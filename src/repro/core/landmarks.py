"""Segment-means landmark selection (paper §2.3, eq. (1)).

``n`` tokens are split into ``m`` contiguous segments and each segment is
mean-pooled. The paper assumes ``n % m == 0`` ("we can pad inputs"); we
implement the general case by zero-padding to the next multiple and dividing
by true per-segment counts, so landmarks are exact means of what is present.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def onehot_segment_sums(x: jnp.ndarray, onehot: jnp.ndarray) -> jnp.ndarray:
    """One-hot segment-sum GEMM with fp32 accumulation: ``onehot`` (m, n) ·
    ``x`` (..., n, d) -> fp32 (..., m, d). The single formula behind every
    landmark-sum site (segment_means, masked_segment_means, and the
    shard-local sums in kernels/sharded.py) so their semantics cannot
    drift."""
    sums = jax.lax.dot_general(
        onehot, x,
        dimension_numbers=(((1,), (x.ndim - 2,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (m, ..., d)
    return jnp.moveaxis(sums, 0, -2)


def segment_counts(n_valid, num_landmarks: int, seg, floor: int = 1) -> jnp.ndarray:
    """True per-segment token counts (m,) fp32 for ``n_valid`` tokens split
    into segments of length ``seg`` (either may be traced). With the default
    ``floor=1`` empty segments clip to 1 so divisions stay finite — matching
    ``segment_means``; ``floor=0`` keeps the raw counts so callers can
    derive segment validity (the decode path's landmark bookkeeping)."""
    return jnp.clip(
        n_valid - jnp.arange(num_landmarks) * seg, floor, seg
    ).astype(jnp.float32)


def segment_means(
    x: jnp.ndarray, num_landmarks: int, via_matmul: bool = False
) -> jnp.ndarray:
    """Mean-pool ``x`` (..., n, d) into (..., m, d) contiguous segment means.

    Two implementations of the same math:

    * reshape path (default): fp32 reshape + mean — cheapest on a single
      device, but the fp32 upcast + axis-split reshape of a *sharded* seq
      axis makes GSPMD all-gather the full (..., n, d) tensor (measured:
      4 x 939MB/layer on the 32k prefill cell, EXPERIMENTS.md §Perf it4).
    * ``via_matmul=True``: means = onehot(seg)ᵀ x / counts as one GEMM with
      fp32 accumulation. The contraction over the sharded n axis partitions
      cleanly (tiny (m, d) psum instead of a full gather) and feeds the MXU.
    """
    n, d = x.shape[-2], x.shape[-1]
    m = int(num_landmarks)
    if m <= 0:
        raise ValueError(f"num_landmarks must be positive, got {m}")
    if n <= m:
        # Degenerate: every token is its own landmark (exact attention).
        return x
    seg = -(-n // m)  # ceil(n / m) tokens per segment
    pad = seg * m - n
    counts = segment_counts(n, m, seg) if pad else float(seg)
    if via_matmul:
        # (m, n) one-hot segment map, in x's dtype so the GEMM stays on the
        # bf16 MXU path; accumulation forced to fp32.
        onehot = (jnp.arange(n) // seg == jnp.arange(m)[:, None]).astype(x.dtype)
        sums = onehot_segment_sums(x, onehot)
        means = sums / (counts[..., :, None] if pad else counts)
        return means.astype(x.dtype)
    xf = x.astype(jnp.float32)
    if pad:
        widths = [(0, 0)] * (x.ndim - 2) + [(0, pad), (0, 0)]
        xf = jnp.pad(xf, widths)
    xf = xf.reshape(*x.shape[:-2], m, seg, d)
    sums = xf.sum(axis=-2)
    means = sums / (counts[..., :, None] if pad else counts)
    return means.astype(x.dtype)


def masked_segment_means(
    x: jnp.ndarray, num_landmarks: int, n_valid
) -> jnp.ndarray:
    """Segment means of ``x[..., :n_valid, :]`` computed on the full padded
    array, with a *traced* ``n_valid``.

    Matches ``segment_means(x[..., :n_valid, :], m, via_matmul=True)``
    numerically while keeping every shape static, so bucketed prefill can
    reuse one XLA program across prompt lengths: positions >= n_valid are
    excluded from the segment sums and the segment length is the dynamic
    ``ceil(n_valid / m)`` the unpadded call would use. Requires
    ``n_valid > m`` (callers keep degenerate prompts on the unpadded exact
    path)."""
    n = x.shape[-2]
    m = int(num_landmarks)
    if m <= 0:
        raise ValueError(f"num_landmarks must be positive, got {m}")
    n_valid = jnp.asarray(n_valid, jnp.int32)
    seg = -(-n_valid // m)  # traced ceil(n_valid / m)
    pos = jnp.arange(n)
    onehot = (
        ((pos // seg)[None, :] == jnp.arange(m)[:, None])
        & (pos < n_valid)[None, :]
    ).astype(x.dtype)
    sums = onehot_segment_sums(x, onehot)
    counts = segment_counts(n_valid, m, seg)
    return (sums / counts[:, None]).astype(x.dtype)


def segment_of(position: jnp.ndarray, n: int, num_landmarks: int) -> jnp.ndarray:
    """Map token positions (0..n-1) to their landmark segment index."""
    seg = -(-n // num_landmarks)
    return position // seg
