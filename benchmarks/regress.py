"""Perf-regression gate: diff working-tree ``BENCH_*.json`` against HEAD.

The bench suites overwrite the top-level ``BENCH_<name>.json`` envelopes in
place, so after

    PYTHONPATH=src python -m benchmarks.run serve --smoke
    PYTHONPATH=src python -m benchmarks.regress

the working-tree file holds the FRESH numbers and ``git show
HEAD:BENCH_<name>.json`` still holds the committed baseline — this module
compares the two, cell by cell, metric by metric, and exits non-zero on
any regression beyond the metric's tolerance band. Every run appends one
line per bench to ``results/bench_trajectory.jsonl`` (provenance-stamped),
the long-term perf history CI uploads as an artifact.

Tolerance policy (see ``metric_policy``): metrics are classified by name —

* structural facts (``*_bytes``, ``*_ticks``, ``*_blocks``, ``*_flops``)
  are layout/scheduling truths, identical run-to-run: ±1% band, either
  direction (a "better" byte count you didn't ask for is also a layout
  change worth failing loudly on);
* wall-clock (``*_s``, ``*_ms``) is lower-better with a generous relative
  band (default 0.75, so a genuine 2x regression always fails while shared
  -runner noise doesn't) plus absolute slack for sub-millisecond values;
* throughput (``*per_s*``) is higher-better, same relative band;
* error/drift metrics are lower-better, ±10% — they're deterministic
  modulo seeding, so a band this tight catches real approximation changes;
* prefix-cache metrics: ``ttft_warm_*`` is wall-clock lower-better (the
  cached-hit latency contract), ``*hit_rate*`` is pinned ±1% (the request
  stream is seeded, so the rate is a scheduling fact, not a measurement);
* chaos-harness counters (``*injection*``, ``*quarantine*``,
  ``*demotion*``, ``*watchdog*``) are pinned ±1% — the fault schedule is
  seeded, so a moved count is a behaviour change, not noise; the surviving
  ``goodput_frac`` is higher-better with the wall band.

Cells/metrics present on only one side are skipped (smoke runs produce a
subset of the committed full grid; new cells have no baseline yet). A
host (backend) mismatch between fresh and baseline skips the wall-clock
and throughput comparisons — structural metrics still apply.

    python -m benchmarks.regress [--names serve,decode] [--wall-tol 0.75]
                                 [--baseline-ref HEAD] [--no-trajectory]
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import subprocess
import sys
import time
from typing import Optional

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
TRAJECTORY = os.path.join(REPO_ROOT, "results", "bench_trajectory.jsonl")
DEFAULT_WALL_TOL = 0.75


@dataclasses.dataclass(frozen=True)
class Policy:
    direction: str   # "lower" = smaller is better, "higher", "both" = pinned
    rel: float       # relative tolerance band
    abs: float       # absolute slack (units of the metric)
    wall: bool = False  # True = skipped when fresh/baseline hosts differ


def metric_policy(metric: str, wall_tol: float = DEFAULT_WALL_TOL) -> Optional[Policy]:
    """Classify a metric by name; None = not gated (informational)."""
    m = metric.lower()
    if m.endswith(("_bytes", "_ticks", "_blocks", "_flops")) or "cost_bytes" in m:
        return Policy("both", 0.01, 0.5)
    # chaos-harness counters are facts of the seeded fault schedule (each
    # firing derives from (seed, site, tick, ordinal)): pinned like
    # structural metrics — drift means the injection points moved, not
    # that the machine got slower
    if ("injection" in m or "quarantine" in m or "demotion" in m
            or "watchdog" in m):
        return Policy("both", 0.01, 0.5)
    # throughput before the wall-clock suffix rule: "tok_per_s" ends in
    # "_s" but is higher-is-better, not a latency
    if "per_s" in m or "throughput" in m or "speedup" in m:
        return Policy("higher", wall_tol, 0.0, wall=True)
    # goodput surviving chaos, as a fraction of the fault-free run: a
    # ratio of two walls on the same host, higher-better with the wall
    # band (absolute goodput_tok_per_s hits the *per_s* rule above)
    if "goodput_frac" in m:
        return Policy("higher", wall_tol, 0.0, wall=True)
    # prefix-cache cells: warm TTFT is the contract the cache exists for —
    # same lower-better wall band as any latency, but named explicitly so
    # the classification is visible and unit-testable; the hit rate is a
    # deterministic scheduling fact (fixed request stream), pinned tight
    if "ttft_warm" in m:
        return Policy("lower", wall_tol, 2e-3, wall=True)
    if "hit_rate" in m:
        return Policy("both", 0.01, 0.01)
    if m.endswith(("_s", "_ms")) or "seconds" in m or "latency" in m:
        return Policy("lower", wall_tol, 2e-3, wall=True)
    if "drift" in m or "err" in m or "residual" in m:
        return Policy("lower", 0.10, 1e-9)
    return None


@dataclasses.dataclass
class Violation:
    bench: str
    cell: str
    metric: str
    baseline: float
    fresh: float
    policy: Policy

    def __str__(self) -> str:
        change = (
            (self.fresh - self.baseline) / self.baseline * 100
            if self.baseline else float("inf")
        )
        return (
            f"REGRESSION {self.bench}[{self.cell}].{self.metric}: "
            f"{self.baseline} -> {self.fresh} ({change:+.1f}%, "
            f"{self.policy.direction}-is-pass band rel={self.policy.rel})"
        )


def compare_cells(
    bench: str,
    fresh: dict,
    baseline: dict,
    *,
    wall_tol: float = DEFAULT_WALL_TOL,
    host_match: bool = True,
) -> tuple[list[Violation], int]:
    """Diff two ``cells`` dicts; returns (violations, metrics compared)."""
    violations: list[Violation] = []
    compared = 0
    for cell, metrics in fresh.items():
        base_cell = baseline.get(cell)
        if not isinstance(base_cell, dict) or not isinstance(metrics, dict):
            continue
        for metric, val in metrics.items():
            base = base_cell.get(metric)
            if not isinstance(base, (int, float)) or not isinstance(
                    val, (int, float)):
                continue
            pol = metric_policy(metric, wall_tol)
            if pol is None or (pol.wall and not host_match):
                continue
            compared += 1
            band = abs(base) * pol.rel + pol.abs
            # "higher" uses a ratio band (base/(1+rel)) so it mirrors
            # "lower": a 2x throughput drop fails just like 2x latency
            bad = (
                val > base + band if pol.direction == "lower"
                else val < base / (1.0 + pol.rel) - pol.abs
                if pol.direction == "higher"
                else abs(val - base) > band
            )
            if bad:
                violations.append(
                    Violation(bench, cell, metric, float(base), float(val),
                              pol))
    return violations, compared


def git_baseline(name: str, ref: str = "HEAD") -> Optional[dict]:
    """The committed envelope at ``ref``, or None if it doesn't exist
    there (new bench: nothing to regress against)."""
    out = subprocess.run(
        ["git", "show", f"{ref}:BENCH_{name}.json"],
        cwd=REPO_ROOT, capture_output=True, text=True, timeout=30,
    )
    if out.returncode != 0:
        return None
    try:
        return json.loads(out.stdout)
    except json.JSONDecodeError:
        return None


def append_trajectory(record: dict, path: str = TRAJECTORY) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(record) + "\n")


def check_bench(
    name: str,
    *,
    ref: str = "HEAD",
    wall_tol: float = DEFAULT_WALL_TOL,
    trajectory: bool = True,
) -> tuple[list[Violation], int]:
    """Gate one bench; returns (violations, metrics compared)."""
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path) as f:
        fresh = json.load(f)
    baseline = git_baseline(name, ref)
    violations: list[Violation] = []
    compared = 0
    if baseline is None:
        print(f"[regress] {name}: no baseline at {ref} (new bench) — skipped")
    elif not isinstance(fresh.get("cells"), dict) or not isinstance(
            baseline.get("cells"), dict):
        print(f"[regress] {name}: list-shaped cells — not gated")
    else:
        host_match = fresh.get("host") == baseline.get("host")
        if not host_match:
            print(f"[regress] {name}: host {baseline.get('host')!r} -> "
                  f"{fresh.get('host')!r}; wall metrics skipped")
        violations, compared = compare_cells(
            name, fresh["cells"], baseline["cells"],
            wall_tol=wall_tol, host_match=host_match,
        )
        print(f"[regress] {name}: {compared} metrics vs {ref}, "
              f"{len(violations)} regression(s)")
    if trajectory:
        append_trajectory({
            "ts": round(time.time(), 3),
            "bench": name,
            "host": fresh.get("host"),
            "provenance": fresh.get("provenance", {}),
            "baseline_ref": ref,
            "metrics_compared": compared,
            "violations": [str(v) for v in violations],
            "cells": fresh.get("cells"),
        })
    return violations, compared


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--names", default=None,
                    help="comma-separated bench names (default: every "
                         "BENCH_*.json in the working tree)")
    ap.add_argument("--baseline-ref", default="HEAD",
                    help="git ref holding the committed baselines")
    ap.add_argument("--wall-tol", type=float, default=DEFAULT_WALL_TOL,
                    help="relative tolerance for wall-clock/throughput "
                         "metrics (CI on shared runners may want it looser)")
    ap.add_argument("--no-trajectory", action="store_true",
                    help="don't append to results/bench_trajectory.jsonl")
    args = ap.parse_args(argv)

    if args.names:
        names = [n.strip() for n in args.names.split(",") if n.strip()]
    else:
        names = sorted(
            os.path.basename(p)[len("BENCH_"):-len(".json")]
            for p in glob.glob(os.path.join(REPO_ROOT, "BENCH_*.json"))
        )
    if not names:
        print("[regress] no BENCH_*.json artifacts found", file=sys.stderr)
        return 2

    all_violations: list[Violation] = []
    for name in names:
        v, _ = check_bench(
            name, ref=args.baseline_ref, wall_tol=args.wall_tol,
            trajectory=not args.no_trajectory,
        )
        all_violations.extend(v)
    for v in all_violations:
        print(v, file=sys.stderr)
    if all_violations:
        print(f"[regress] FAIL: {len(all_violations)} regression(s)",
              file=sys.stderr)
        return 1
    print("[regress] PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
