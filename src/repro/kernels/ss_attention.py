"""Pallas TPU kernels for spectral-shifting attention (DESIGN.md §3).

Two kernels cover the only O(n) GEMMs in the method; everything else is
O(c^2)-small and stays in jnp:

* ``landmark_summary``  (B-side): ``BV = softmax(Q~ K^T) @ V``. The c landmark
  queries are VMEM-resident; K/V stream HBM->VMEM in ``block_n`` chunks with
  the online-softmax (flash) recurrence, so no (c, n) intermediate ever
  exists. Grid = (batch, n_blocks), n innermost so the fp32 accumulators in
  VMEM scratch persist across the stream. ``return_stats=True`` additionally
  emits the per-row online-softmax statistics ``(m, l)`` — the residuals the
  custom-VJP backward kernel (ss_attention_bwd.py) uses to reconstruct the
  softmax factor exactly without a second reduction pass.

* ``query_side`` (F-side): ``out = softmax(Q K~^T) @ M + delta * V`` with
  ``M = U_ss (BV)`` (c x dv, VMEM-resident). Softmax axis is c (fully
  resident) so each Q/V block needs exactly one HBM read and one write —
  the (n, c) matrix F is never materialized.

Both kernels take ``seg`` (landmark segment length, 0 = bidirectional) for
the segment-causal variant: landmark row r only attends keys in segments
<= r (B-side), and query position p only attends landmark columns
<= segment_of(p) (F-side) — the same masks ``core.attention._ss_factors``
applies on the jnp path, evaluated inside the stream.

Dynamic bounds (context parallelism + bucketed prefill): the kernels
additionally accept *traced* scalar coordinates, shipped to the kernel as a
tiny SMEM input so no per-length recompilation or (c, n) mask tensor is ever
needed:

* ``kv_offset`` / ``kv_valid`` (B-side): global position of the first local
  key and the global end of valid keys. A shard_map shard passes its shard
  offset (ragged last shards mask the tail); bucketed prefill passes
  ``kv_valid = n_valid`` so padded zero-keys never enter the softmax.
* ``q_offset`` (F-side): global position of the first local query row,
  replacing the static decode-convention ``n_k - n`` offset.

Block shapes default to MXU/VPU-aligned sizes (lane dim = head_dim, ideally
a multiple of 128; sublane blocks multiples of 8). Kernels are validated on
CPU in interpret mode against ``ref.py``; TPU is the compile target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _bounds_array(*vals) -> jnp.ndarray:
    """Pack scalar coordinates (Python ints or traced scalars) into the
    (1, len(vals)) int32 SMEM operand the dynamic-bounds kernels read."""
    return jnp.stack(
        [jnp.asarray(v, jnp.int32).reshape(()) for v in vals]
    ).reshape(1, len(vals))


def _b_side_mask(shape, i, *, block_n: int, seg: int, kv_offset=0,
                 kv_valid=None, row_offset=0):
    """Key-validity x segment-causal mask for one streamed B-side block
    (shape (c, bn) at block index ``i``), or None when nothing is masked.
    ``kv_offset``/``kv_valid`` are *global* key coordinates and may be
    Python ints (static path) or traced scalars (dynamic bounds);
    ``row_offset`` is the global landmark index of the block's first row
    (non-zero when the c axis is grid-tiled via ``block_c``). Shared by
    the forward step and the backward kernel so the two can never drift
    apart."""
    if kv_valid is None and not seg:
        return None
    # Global position of each streamed key column.
    kv_pos = kv_offset + i * block_n + jax.lax.broadcasted_iota(
        jnp.int32, shape, 1
    )
    mask = None
    if kv_valid is not None:
        # Keys past the valid end (zero-padded tail / bucketed prefill pad).
        mask = kv_pos < kv_valid
    if seg:
        # Segment-causal: landmark row r (the mean of segment r) attends
        # keys up to the end of its own segment only.
        row = jax.lax.broadcasted_iota(jnp.int32, shape, 0) + row_offset
        cmask = kv_pos < (row + 1) * seg
        mask = cmask if mask is None else jnp.logical_and(mask, cmask)
    return mask


# --------------------------------------------------------------------------
# B-side: landmark summary with online softmax over the streamed n axis.
# --------------------------------------------------------------------------
def _landmark_summary_step(
    q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr, *,
    scale: float, block_n: int, seg: int, kv_offset, kv_valid,
    n_index, row_offset,
):
    """One online-softmax step over key/value block ``n_index`` (shared by
    the plain and the stats-emitting kernel). ``row_offset`` is the global
    landmark index of q_ref's first row (c-tiled grids)."""
    i = n_index

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                      # (c, d)
    k = k_ref[0].astype(jnp.float32)                      # (bn, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                             # (c, bn)

    mask = _b_side_mask(
        s.shape, i, block_n=block_n, seg=seg, kv_offset=kv_offset,
        kv_valid=kv_valid, row_offset=row_offset,
    )
    if mask is not None:
        s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                                   # (c, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                                # (c, bn)
    if mask is not None:
        # exp underflows to 0 for real scores, but a fully-masked row in the
        # first block has m_new == s == -inf => exp(0) == 1; zero explicitly.
        p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                        # (c, 1)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (c, dv)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new


def _landmark_summary_kernel(
    *refs,
    scale: float,
    n_valid: int,
    block_n: int,
    block_c: int,
    seg: int,
    dyn: bool,
    stats: bool,
):
    """Shared kernel body. Ref layout (inputs, outputs, scratch):

        [bounds (1,2) SMEM if dyn], q (1,bc,d), k (1,bn,d), v (1,bn,dv),
        o (1,bc,dv) [, m_out (1,bc,1), l_out (1,bc,1) if stats],
        m_scr (bc,1), l_scr (bc,1), acc_scr (bc,dv)

    ``block_c`` > 0 means the landmark axis is grid-tiled: the grid is
    (b, c_tiles, n_blocks) with the streamed n axis innermost (scratch
    re-inits per tile at n block 0), otherwise (b, n_blocks).
    """
    c_tiled = block_c > 0
    n_ax = 2 if c_tiled else 1
    n_index = pl.program_id(n_ax)
    row_offset = pl.program_id(1) * block_c if c_tiled else 0
    if dyn:
        bounds_ref, *refs = refs
        kv_offset = bounds_ref[0, 0]
        # Clamp the global bound by the local pre-block-padding length:
        # keys at local index >= n_valid are the zero tail the wrapper
        # padded to a block multiple, and their global positions can sit
        # below the global valid end on non-final shards.
        kv_valid = jnp.minimum(bounds_ref[0, 1], kv_offset + n_valid)
    else:
        kv_offset = 0
        kv_valid = n_valid if n_valid % block_n else None
    if stats:
        q_ref, k_ref, v_ref, o_ref, mo_ref, lo_ref, m_scr, l_scr, acc_scr = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs

    _landmark_summary_step(
        q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr,
        scale=scale, block_n=block_n, seg=seg, kv_offset=kv_offset,
        kv_valid=kv_valid, n_index=n_index, row_offset=row_offset,
    )

    @pl.when(n_index == pl.num_programs(n_ax) - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        if stats:
            mo_ref[0] = m_scr[...]
            lo_ref[0] = l_scr[...]


def landmark_summary(
    q_l: jnp.ndarray,  # (b, c, d)
    k: jnp.ndarray,    # (b, n, d)
    v: jnp.ndarray,    # (b, n, dv)
    *,
    scale: float,
    block_n: int = 512,
    block_c: int = 0,
    causal: bool = False,
    interpret: bool = False,
    return_stats: bool = False,
    kv_offset=None,
    kv_valid=None,
    seq_len_k: int = 0,
):
    """BV = softmax(Q~ K^T * scale) @ V via a flash-style streamed kernel.

    ``causal=True`` applies the segment-causal B-mask (landmark r sees keys
    < (r+1)*seg with seg = ceil(seq_len_k/c)). ``return_stats=True`` returns
    ``(bv, m, l)`` with ``m``/``l`` (b, c, 1) fp32 — the online-softmax max
    and denominator, saved as custom-VJP residuals.

    ``kv_offset``/``kv_valid`` (optional, possibly traced scalars) place the
    local keys in global coordinates: key column j has global position
    ``kv_offset + j`` and is masked unless it is < ``kv_valid``. A shard_map
    shard passes its shard offset; bucketed prefill passes the prompt length.
    ``seq_len_k`` is the *global* key length the causal segment geometry is
    built from (defaults to the local n).

    ``block_c`` (0 = disabled) tiles the landmark rows over an extra grid
    axis: rows are independent online-softmax streams, so each (1, block_c)
    tile re-runs the n stream with a block_c-row scratch — smaller VMEM
    accumulators at the price of re-reading K/V per tile. Only used when it
    divides c; an autotune candidate, not a default.
    """
    b, c, d = q_l.shape
    n, dv = k.shape[1], v.shape[2]
    n_k = seq_len_k or n
    seg = -(-n_k // c) if causal else 0
    block_n = min(block_n, n)
    n_pad = -n % block_n
    if n_pad:
        k = jnp.pad(k, ((0, 0), (0, n_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, n_pad), (0, 0)))
    n_blocks = (n + n_pad) // block_n

    c_tiled = 0 < block_c < c and c % block_c == 0
    bc = block_c if c_tiled else c
    if c_tiled:
        grid = (b, c // bc, n_blocks)
        q_idx = lambda bi, ci, i: (bi, ci, 0)      # noqa: E731
        kv_idx = lambda bi, ci, i: (bi, i, 0)      # noqa: E731
    else:
        grid = (b, n_blocks)
        q_idx = lambda bi, i: (bi, 0, 0)           # noqa: E731
        kv_idx = lambda bi, i: (bi, i, 0)          # noqa: E731

    dyn = kv_offset is not None or kv_valid is not None
    in_specs = [
        pl.BlockSpec((1, bc, d), q_idx),
        pl.BlockSpec((1, block_n, d), kv_idx),
        pl.BlockSpec((1, block_n, dv), kv_idx),
    ]
    inputs = [q_l, k, v]
    if dyn:
        in_specs.insert(0, pl.BlockSpec(memory_space=pltpu.SMEM))
        off = kv_offset if kv_offset is not None else 0
        # kv_valid defaults to "all local keys valid" in GLOBAL coordinates
        # (off + n, not n): the two bounds are independently optional.
        inputs.insert(
            0,
            _bounds_array(off, kv_valid if kv_valid is not None else off + n),
        )
    scratch_shapes = [
        pltpu.VMEM((bc, 1), jnp.float32),
        pltpu.VMEM((bc, 1), jnp.float32),
        pltpu.VMEM((bc, dv), jnp.float32),
    ]
    kernel = functools.partial(
        _landmark_summary_kernel, scale=scale, n_valid=n, block_n=block_n,
        block_c=bc if c_tiled else 0, seg=seg, dyn=dyn, stats=return_stats,
    )
    if not return_stats:
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bc, dv), q_idx),
            out_shape=jax.ShapeDtypeStruct((b, c, dv), v.dtype),
            scratch_shapes=scratch_shapes,
            interpret=interpret,
        )(*inputs)

    stat_spec = pl.BlockSpec((1, bc, 1), q_idx)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, bc, dv), q_idx),
            stat_spec,
            stat_spec,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, c, dv), v.dtype),
            jax.ShapeDtypeStruct((b, c, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, c, 1), jnp.float32),
        ),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*inputs)


# --------------------------------------------------------------------------
# F-side: fused softmax(Q K~^T) @ M + delta * V over streamed Q/V blocks.
# --------------------------------------------------------------------------
def _query_side_probs(q_ref, kl_ref, *, scale, block_n, seg, pos_offset):
    """Block-resident softmax factor P (bn, c), with the segment-causal
    F-mask applied when ``seg`` is set. ``pos_offset`` may be a Python int
    or a traced scalar (dynamic bounds). Shared with the backward kernel."""
    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                      # (bn, d)
    kl = kl_ref[0].astype(jnp.float32)                    # (c, d)
    s = jax.lax.dot_general(
        q, kl, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                             # (bn, c)
    mask = None
    if seg:
        # Query at position p attends landmark columns <= p // seg only.
        qpos = (
            i * block_n
            + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            + pos_offset
        )
        lseg = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = lseg <= qpos // seg
        s = jnp.where(mask, s, _NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    if mask is not None:
        p = jnp.where(mask, p, 0.0)
    return p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)


def _query_side_kernel(
    *refs,
    scale: float,
    block_n: int,
    seg: int,
    pos_offset: int,
    dyn: bool,
):
    """Ref layout: [bounds (1,1) SMEM if dyn], q (1,bn,d), kl (1,c,d),
    m (1,c,dv), v (1,bn,dv), delta (1,1,1), o (1,bn,dv)."""
    if dyn:
        bounds_ref, *refs = refs
        pos_offset = bounds_ref[0, 0]
    q_ref, kl_ref, m_ref, v_ref, delta_ref, o_ref = refs
    p = _query_side_probs(
        q_ref, kl_ref, scale=scale, block_n=block_n, seg=seg,
        pos_offset=pos_offset,
    )
    out = jax.lax.dot_general(
        p, m_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                     # (bn, dv)
    out = out + delta_ref[0, 0, 0] * v_ref[0].astype(jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)


def query_side(
    q: jnp.ndarray,      # (b, n, d)
    k_l: jnp.ndarray,    # (b, c, d)
    m_mat: jnp.ndarray,  # (b, c, dv)
    v: jnp.ndarray,      # (b, n, dv)
    delta: jnp.ndarray,  # (b, 1, 1)
    *,
    scale: float,
    block_n: int = 512,
    causal: bool = False,
    seq_len_k: int = 0,
    interpret: bool = False,
    q_offset=None,
) -> jnp.ndarray:
    """out = softmax(Q K~^T * scale) @ M + delta * V, one HBM pass over Q/V.

    ``causal=True`` applies the segment-causal F-mask; ``seq_len_k`` is the
    key-sequence length the landmark segments were built from (defaults to
    n, i.e. self-attention; a longer context puts the queries at its tail,
    the decode convention). ``q_offset`` (optional, possibly traced scalar)
    *replaces* the static tail offset with the global position of q row 0 —
    the shard_map driver passes its shard offset here.
    """
    b, n, d = q.shape
    c, dv = k_l.shape[1], v.shape[2]
    n_k = seq_len_k or n
    seg = -(-n_k // c) if causal else 0
    pos_offset = n_k - n if causal else 0
    block_n = min(block_n, n)
    n_pad = -n % block_n
    if n_pad:
        q = jnp.pad(q, ((0, 0), (0, n_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, n_pad), (0, 0)))
    n_blocks = (n + n_pad) // block_n

    dyn = q_offset is not None
    in_specs = [
        pl.BlockSpec((1, block_n, d), lambda bi, i: (bi, i, 0)),
        pl.BlockSpec((1, c, d), lambda bi, i: (bi, 0, 0)),
        pl.BlockSpec((1, c, dv), lambda bi, i: (bi, 0, 0)),
        pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
        pl.BlockSpec((1, 1, 1), lambda bi, i: (bi, 0, 0)),
    ]
    inputs = [q, k_l, m_mat, v, delta.astype(jnp.float32)]
    if dyn:
        in_specs.insert(0, pl.BlockSpec(memory_space=pltpu.SMEM))
        inputs.insert(0, _bounds_array(q_offset))
    kernel = functools.partial(
        _query_side_kernel, scale=scale, block_n=block_n, seg=seg,
        pos_offset=pos_offset, dyn=dyn,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_n, dv), lambda bi, i: (bi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n + n_pad, dv), q.dtype),
        interpret=interpret,
    )(*inputs)
    return out[:, :n] if n_pad else out
