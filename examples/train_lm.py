"""End-to-end LM training driver.

Default (CPU-feasible): a ~20M-param qwen2-family model, 300 steps on the
deterministic synthetic stream, training THROUGH the paper's spectral-shift
attention (causal segment variant), with checkpointing and a loss-curve dump.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full-100m]

``--full-100m`` switches to a ~100M config (d_model=768, 12 layers, 1024
seq) — sized for a real accelerator; the step math is identical.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--attention", default="spectral_shift",
                    choices=["full", "chunked", "nystrom", "spectral_shift"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--out", default="results/train_lm_loss.json")
    args = ap.parse_args()

    if args.full_100m:
        cfg = ModelConfig(
            name="lm-100m", num_layers=12, d_model=768, num_heads=12,
            num_kv_heads=4, d_ff=2048, vocab_size=32000, num_landmarks=64,
            attention_impl=args.attention, compute_dtype="bfloat16",
        )
        shape = ShapeConfig("train_4k", 1024, 16, "train")
    else:
        cfg = ModelConfig(
            name="lm-20m", num_layers=4, d_model=256, num_heads=8,
            num_kv_heads=4, d_ff=1024, vocab_size=2048, num_landmarks=32,
            attention_impl=args.attention, compute_dtype="float32",
            remat="none",
        )
        shape = ShapeConfig("train_4k", 256, 8, "train")

    tcfg = TrainConfig(
        learning_rate=1e-3, warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps, checkpoint_dir=args.ckpt_dir,
        checkpoint_every=max(args.steps // 3, 50),
    )
    trainer = Trainer(cfg, tcfg, shape, make_local_mesh(1))
    history = trainer.run(args.steps, log_every=25)
    trainer.save(blocking=True)

    losses = [h["loss"] for h in history]
    window = max(len(losses) // 10, 1)
    print(f"\n[train_lm] attention={args.attention}")
    print(f"  loss: first{window}-avg {sum(losses[:window]) / window:.4f}"
          f" -> last{window}-avg {sum(losses[-window:]) / window:.4f}")
    print(f"  checkpoints: {trainer.ckpt.all_steps()} in {args.ckpt_dir}")
    import os
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"config": cfg.name, "attention": args.attention,
                   "loss": losses}, f)
    print(f"  loss curve -> {args.out}")


if __name__ == "__main__":
    main()
