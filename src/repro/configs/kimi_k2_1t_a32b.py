"""Kimi-K2 1T-A32B [arXiv:2501.kimi2, paper-table]: trillion-param MoE.

Per the assignment sheet: GQA (64H, kv=8), 384 routed experts top-8,
expert d_ff=2048; we add 1 shared expert per the K2 report. head_dim =
d_model // num_heads = 112 as given (the sheet's GQA spec, not MLA).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    moe=True, num_experts=384, num_shared_experts=1, top_k=8, moe_d_ff=2048,
    capacity_factor=1.0, rope_theta=5e4,
    attention_impl="chunked",
)
