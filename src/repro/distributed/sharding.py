"""Logical-axis sharding: MaxText-style rules mapping logical tensor axes to
mesh axes, applied to parameters (via ``ParamSpec.axes``) and activations
(via ``logical_constraint`` calls inside model code).

Mesh axes: ("pod",) "data", "model" — see launch/mesh.py. The rules encode
DP (batch over pod+data), FSDP/ZeRO (weight embed dim over data), TP (heads /
ff / vocab over model), EP (experts over data) and SP (long-context sequence
over data). Activations only use constraints at layer boundaries; XLA GSPMD
propagates the rest.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes (tuple => sharded over multiple mesh axes).
# Entries may be overridden per-run (e.g. SP for long_500k).
DEFAULT_RULES: dict[str, object] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "data",          # sequence-parallel sites (long-context decode)
    "embed_act": None,
    "heads_act": "model",
    "ff_act": "model",
    "vocab_act": "model",
    "experts_act": "data",
    # parameters
    "vocab": "model",
    "embed": "data",           # FSDP shard of weight matrices
    "embed_unsharded": None,   # MoE expert weights keep d unsharded (E->data)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "moe_ff": "model",
    "experts": ("pod", "data"),  # EP spans pods: expert weights + moments
                                 # must NOT replicate across pods (1T-scale)
    "kv_lora": None,
    "layers": None,
    "cache_seq": None,         # KV-cache sequence dim ("data" under SP)
    "cache_batch": ("pod", "data"),
}

def seq_axis_sharded(mesh: Mesh, overrides: Optional[dict] = None) -> bool:
    """True when the activation sequence axis ("seq" rule, after overrides)
    maps onto mesh axes of total size > 1. Used to auto-select the GEMM
    segment-means path (``landmark_via_matmul``): the reshape path's fp32
    axis-split makes GSPMD all-gather the full (n, d) tensor per layer when
    the sequence is sharded (core/landmarks.py)."""
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    v = rules.get("seq")
    if v is None:
        return False
    axes = (v,) if isinstance(v, str) else tuple(v)
    size = 1
    for a in axes:
        if a in mesh.axis_names:
            size *= mesh.shape[a]
    return size > 1


def apply_seq_sharding_config(cfg, mesh: Mesh, overrides: Optional[dict] = None,
                              log=None):
    """Context-parallel implications for a ModelConfig, in one place (used by
    both the Trainer and dryrun.run_cell so compile-time stats model the same
    kernel route the trainer runs):

    * ``landmark_via_matmul=True`` — see ``seq_axis_sharded``;
    * fused attention STAYS fused: seq-sharded cells route through the
      shard_map context-parallel driver (kernels/sharded.py) via the dispatch
      registry, so ``attention_backend`` and ``remat="ss_stats"`` are left
      untouched (the sharded custom-VJP ops emit the same tagged residuals).
      ``seq_shard_fused=False`` restores the legacy downgrade to the
      jnp-GSPMD route (with ``remat="ss_stats"`` widened to ``"full"``, since
      the jnp route emits no tagged residuals and the save-only-these-names
      policy would silently save nothing).

    Returns ``cfg`` unchanged when the sequence axis is not sharded.
    """
    import dataclasses

    if not seq_axis_sharded(mesh, overrides):
        return cfg
    if not cfg.landmark_via_matmul:
        if log:
            log.info("sequence axis is sharded: enabling landmark_via_matmul")
        cfg = dataclasses.replace(cfg, landmark_via_matmul=True)
    if (cfg.attention_impl == "spectral_shift_fused"
            and cfg.attention_backend in ("auto", "fused")):
        if getattr(cfg, "seq_shard_fused", True):
            if log:
                log.info(
                    "sequence axis is sharded: fused attention routes through "
                    "the shard_map context-parallel kernels"
                )
            import jax

            from repro.configs.base import resolve_remat

            if (resolve_remat(cfg.remat) == "ss_stats"
                    and cfg.attention_backend == "auto"
                    and jax.default_backend() == "cpu"):
                # The dispatch heuristic routes context-parallel cells to
                # jnp-GSPMD on CPU, and the jnp route emits no tagged
                # residuals — the save-only-these-names policy would
                # silently save nothing. Widen explicitly (as the legacy
                # downgrade did); a forced fused/interpret/sharded backend
                # keeps ss_stats.
                if log:
                    log.warning(
                        "remat='ss_stats' has no tagged residuals on the "
                        "jnp route the CPU heuristic selects; using "
                        "remat='full'"
                    )
                cfg = dataclasses.replace(cfg, remat="full")
            return cfg
        if log:
            log.info(
                "sequence axis is sharded and seq_shard_fused=False: "
                "forcing attention_backend=jnp"
            )
        cfg = dataclasses.replace(cfg, attention_backend="jnp")
        from repro.configs.base import resolve_remat

        # Resolve "auto" before the guard: REMAT_DEFAULTS maps TPU/GPU to
        # ss_stats, which has no tagged residuals on this forced-jnp route.
        if resolve_remat(cfg.remat) == "ss_stats":
            if log:
                log.warning(
                    "remat='ss_stats' has no tagged residuals on the jnp "
                    "route; using remat='full'"
                )
            cfg = dataclasses.replace(cfg, remat="full")
    return cfg


_state = threading.local()


def _rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def sharding_rules(mesh: Mesh, overrides: Optional[dict] = None):
    """Activate logical-axis sharding for model code within this context."""
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    # Drop mesh axes that don't exist (single-pod mesh has no "pod").
    def fix(v):
        if v is None:
            return None
        axes = (v,) if isinstance(v, str) else tuple(v)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    _state.rules = {k: fix(v) for k, v in rules.items()}
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = None
        _state.mesh = None


def active_seq_sharding():
    """(mesh, seq_axes, lead_axes) for the fused-attention shard_map driver,
    read from the active ``sharding_rules`` context at trace time.

    ``seq_axes`` is the tuple of mesh axes the "seq" rule maps onto — empty
    when there is no active context or the axes span <= 1 devices.
    ``lead_axes`` are the axes for attention's flattened (batch*heads)
    leading dim: the "batch" + "heads_act" rules minus any axis the sequence
    already claims (a mesh axis may appear once)."""
    mesh, rules = _mesh(), _rules()
    if mesh is None or rules is None:
        return None, (), ()

    def axes_of(rule):
        v = rules.get(rule)
        if v is None:
            return ()
        return (v,) if isinstance(v, str) else tuple(v)

    seq_axes = tuple(a for a in axes_of("seq") if a in mesh.axis_names)
    size = 1
    for a in seq_axes:
        size *= mesh.shape[a]
    if size <= 1:
        return mesh, (), ()
    used = set(seq_axes)
    lead = []
    for rule in ("batch", "heads_act"):
        for a in axes_of(rule):
            if a in mesh.axis_names and a not in used:
                used.add(a)
                lead.append(a)
    return mesh, seq_axes, tuple(lead)


def spec_for(axes: tuple) -> P:
    """Logical axes tuple -> PartitionSpec under the active rules."""
    rules = _rules() or {}
    used: set = set()
    parts = []
    for ax in axes:
        target = rules.get(ax) if ax is not None else None
        # A mesh axis may appear only once in a PartitionSpec.
        if target is not None:
            flat = (target,) if isinstance(target, str) else tuple(target)
            flat = tuple(a for a in flat if a not in used)
            used.update(flat)
            target = None if not flat else (flat if len(flat) > 1 else flat[0])
        parts.append(target)
    return P(*parts)


def logical_constraint(x: jax.Array, axes: tuple) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside a mesh ctx."""
    mesh = _mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(axes))
    )


def named_sharding(mesh: Mesh, axes: tuple) -> NamedSharding:
    return NamedSharding(mesh, spec_for(axes))


def _axis_size(mesh: Mesh, target) -> int:
    if target is None:
        return 1
    names = (target,) if isinstance(target, str) else target
    n = 1
    for name in names:
        n *= mesh.shape[name]
    return n


def divisible_spec(mesh: Mesh, axes: tuple, shape: tuple) -> P:
    """PartitionSpec under the rules, dropping any dim whose size is not
    divisible by its mesh-axis product (jit in_shardings require exact
    divisibility; e.g. 28 heads cannot shard over a 16-way model axis)."""
    base = spec_for(axes)
    parts = []
    for dim, target in zip(shape, tuple(base) + (None,) * (len(shape) - len(base))):
        if target is not None and dim % _axis_size(mesh, target) != 0:
            target = None
        parts.append(target)
    return P(*parts)


def shardings_for(mesh: Mesh, axes_tree, abstract_tree):
    """NamedShardings for an abstract pytree, divisibility-validated."""
    return jax.tree.map(
        lambda axes, leaf: NamedSharding(
            mesh, divisible_spec(mesh, axes, leaf.shape)
        ),
        axes_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def param_shardings(mesh: Mesh, axes_tree):
    """Map a pytree of logical-axes tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: named_sharding(mesh, axes),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )
