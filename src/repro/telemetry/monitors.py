"""Online approximation-quality monitors for spectral-shift serving.

The method's pitch over plain Nystrom attention is a tighter error bound
when the softmax spectrum decays *slowly* — which makes approximation
quality a property of the traffic, not the code. ``benchmarks/bench_drift``
measures it offline; these monitors track the same two signals online, per
request, from state the engine already computes:

* **Rebase drift residual** (``DriftMonitor``): a frozen-mode segment
  boundary rebase recomputes the active-row stats *exactly* — so the
  difference between the streamed (stale) row and the exact recompute is a
  free online measurement of the B-side staleness bench_drift calls
  ``bv_drift``. ``bv_row_residual`` is the shared formula (max relative
  per-row BV error, identical to the offline bench), evaluated on the
  O(c*d) stats leaves only — never the horizon.

* **Landmark-mass concentration** (``SpectrumMonitor``): how evenly the
  landmark-to-key softmax mass spreads across landmark rows. Per row the
  true softmax mass is ``Z_r = l_r * exp(m_r)`` (the online-softmax
  partials the cache already carries); normalizing over reached rows gives
  a distribution whose top-1 share and participation ratio proxy the
  softmax spectrum decay: mass spread thin across many landmarks is the
  paper's slow-decay regime, where the spectral-shift correction is doing
  the most work and frozen-mode drift deserves attention. Tracked as an
  EMA so one odd request doesn't whipsaw the gauge.

Both are pure-numpy host probes over (c,)-sized state: cheap enough to run
on every boundary rebase / retirement, and only instantiated when
``ServeConfig.telemetry`` is on.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

_EPS = 1e-30


def bv_from_stats(l, acc):
    """BV rows from online-softmax partials: ``acc / max(l, eps)``."""
    l = np.asarray(l, np.float64)
    acc = np.asarray(acc, np.float64)
    return acc / np.maximum(l, _EPS)


def bv_row_residual(pre, post, rows: Sequence[int]) -> float:
    """Max relative BV-row residual between two stats snapshots, over the
    given landmark rows — the same per-row formula as bench_drift:

        max_r  || bv_pre[..., r, :] - bv_post[..., r, :] ||
               / max(|| bv_post[..., r, :] ||, eps)

    ``pre``/``post`` are ``(l, acc)`` pairs with the landmark axis at -2;
    arbitrary leading (layer/batch/head) axes reduce through the max."""
    bv_pre = bv_from_stats(*pre)[..., list(rows), :]
    bv_post = bv_from_stats(*post)[..., list(rows), :]
    num = np.linalg.norm(bv_pre - bv_post, axis=-1)
    den = np.maximum(np.linalg.norm(bv_post, axis=-1), _EPS)
    return float(np.max(num / den))


def spectrum_mass(m, l, reached: int) -> tuple[float, float]:
    """(top1_share, effective_landmark_fraction) of the landmark softmax
    mass over the first ``reached`` rows.

    Row mass in log space is ``m_r + log(l_r)`` (anchor-corrected, so rows
    with different online-softmax anchors compare correctly); softmaxing
    over rows gives the mass distribution ``p``. Returns its max share and
    the participation ratio ``1 / sum(p^2)`` as a fraction of ``reached``
    (1.0 = perfectly even mass = the slow-decay regime; -> 1/reached = all
    mass on one landmark). Leading (layer/head) axes are averaged."""
    reached = max(int(reached), 1)
    m = np.asarray(m, np.float64)[..., :reached, :]
    l = np.asarray(l, np.float64)[..., :reached, :]
    logz = m + np.log(np.maximum(l, _EPS))
    logz = logz - np.max(logz, axis=-2, keepdims=True)
    p = np.exp(logz)
    p = p / np.maximum(np.sum(p, axis=-2, keepdims=True), _EPS)
    top1 = float(np.mean(np.max(p, axis=-2)))
    pr = 1.0 / np.maximum(np.sum(p * p, axis=-2), _EPS)
    eff = float(np.mean(pr)) / reached
    return top1, eff


class DriftMonitor:
    """Registry-backed accumulator of per-rebase drift residuals."""

    def __init__(self, registry):
        from repro.telemetry.metrics import RATIO_BUCKETS

        self.hist = registry.histogram(
            "drift_rebase_residual",
            help="relative BV-row staleness cleared by each boundary rebase",
            buckets=RATIO_BUCKETS,
        )
        self.last = registry.gauge(
            "drift_rebase_residual_last",
            help="most recent rebase residual",
        )

    def observe(self, residual: float) -> None:
        self.hist.observe(residual)
        self.last.set(residual)


class SpectrumMonitor:
    """EMA of landmark-softmax mass concentration (spectrum-decay proxy)."""

    def __init__(self, registry, alpha: float = 0.1):
        self.alpha = alpha
        self._top1 = None
        self._eff = None
        self.top1 = registry.gauge(
            "spectrum_mass_top1_ema",
            help="EMA of the largest landmark's softmax-mass share",
        )
        self.eff = registry.gauge(
            "spectrum_eff_landmark_frac_ema",
            help="EMA participation-ratio fraction of reached landmarks "
                 "(near 1 = evenly spread mass = slow spectrum decay)",
        )
        self.observations = registry.counter(
            "spectrum_observations_total",
            help="spectrum-mass probe evaluations",
        )

    def observe(self, m, l, reached: int) -> None:
        top1, eff = spectrum_mass(m, l, reached)
        a = self.alpha
        self._top1 = top1 if self._top1 is None else a * top1 + (1 - a) * self._top1
        self._eff = eff if self._eff is None else a * eff + (1 - a) * self._eff
        self.top1.set(self._top1)
        self.eff.set(self._eff)
        self.observations.inc()
