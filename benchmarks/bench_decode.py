"""Per-token decode latency and HBM traffic vs cache horizon:
recompute vs streaming state x gather vs gather-free paged ticks.

The legacy spectral-shift decode rebuilds the landmark-to-key softmax
``B = softmax(Q~ K^T)`` and its value summary ``B V`` over the whole cache
horizon every tick — O(c*S*d) per token, linear in S with slope c. The
streaming decode state (serve/decode_state.py) carries per-landmark
online-softmax partials in the cache instead:

    exact   — flash-append + ONE row recomputed per tick: O(S*d + c*d),
              linear with slope 1 (a c-fold cut), token-identical greedy;
    frozen  — fully streamed O(c*d) per tick (near-flat in S) plus an
              amortized two-row rebase at segment boundaries.

Storage/tick-program cells (``impl``):

    dense   — donated jitted ``decode_step`` on a lane-dense cache (pure
              decode-math cost, no paging at all);
    gather  — block-pool storage, legacy tick: gather a transient dense
              view -> batched step -> scatter the touched block
              (``PagedKVCache.make_fused_step``). O(S) HBM bytes per tick
              in EVERY mode (this was called "paged" in pre-PR5 CSVs);
    paged   — gather-free tick (``make_paged_step`` +
              ``ServeConfig.decode_impl="paged"``): the block-table Pallas
              kernel streams K/V straight from the pools, the new token
              commits via a single-block scatter. Frozen-mode ticks touch
              O(c*d) dense state plus ONE block — per-token bytes
              independent of the horizon. (No ``recompute`` cell: that
              mode needs the dense B rebuild and stays on gather.)

Each cell reports measured ``per_token_ms`` and modelled ``per_token_bytes``
— an analytic per-tick HBM-traffic account (view assembles, horizon reads,
block commits, dense-leaf read+write) computed from the storage layout;
XLA cost analysis is useless here because scatter/dynamic-update ops are
charged at full-operand size regardless of in-place aliasing. On CPU the
paged kernel runs in interpret mode, so its measured exact-mode wall-clock
carries interpreter overhead by design (TPU is the compile target); the
frozen-mode cells and every bytes column are layout facts, not interpreter
artifacts. Caches are seeded synthetically (random K/V + consistent
landmark sums + exact streaming stats) so the 32k cell doesn't need a
32k-token prefill. Frozen per-token numbers charge the boundary rebase at
its amortized steady-state rate (one rebase per ``seg = ceil(S/c)``
tokens), reported alongside as ``rebase_ms``.

Besides CSV rows, ``run`` writes a machine-readable perf trajectory to the
repo-level ``BENCH_decode.json`` (mode x horizon x impl -> ms/token,
bytes/token) so future PRs can diff serving perf without re-parsing CSVs.

    PYTHONPATH=src python -m benchmarks.run --only decode
    REPRO_BENCH_SMOKE=1 ... (one tiny horizon for CI)
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig, reduced
from repro.configs.registry import get_config
from repro.models.attention import _broadcast_kv
from repro.models.model import model_specs
from repro.models.params import init_params
from repro.serve.decode import decode_step
from repro.serve.decode_state import (
    landmark_counts,
    landmark_means,
    make_rebase_fn,
    recompute_stats,
    segment_len,
)
from repro.serve.paged import BlockAllocator, PagedKVCache, ZERO_BLOCK

MODES = ("recompute", "exact", "frozen")

_cells: dict[str, dict] = {}


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def _record(rows, impl, horizon, mode, metric, value):
    rows.append(f"decode,{impl}_h{horizon}_{mode},{metric},{value:.3f}")
    _cells.setdefault(f"{impl}|{mode}|{horizon}", {})[metric] = round(value, 4)


def _setup():
    # scan_layers=False: per-layer cache leaves are separate donated jit
    # arguments, so the K/V updates alias in place — a layer scan routes
    # the cache through scan outputs, which forces an O(S) copy per tick
    # that would mask the attention-cost differences this bench measures.
    cfg = dataclasses.replace(
        reduced(get_config("qwen2-7b")), capacity_factor=100.0,
        decode_attention_impl="spectral_shift", scan_layers=False,
    )
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


@functools.partial(jax.jit, static_argnames=("cfg", "s_max", "pos"))
def _synthetic_cache(cfg, s_max: int, pos: int, key):
    """B=1 decode cache at write position ``pos+1``: random K/V, landmark
    sums consistent with them, and exact streaming stats — everything a
    decode tick reads, without paying an O(S) prefill at bench setup."""
    h, hkv, dh, c = (
        cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
        cfg.num_landmarks,
    )
    seg = segment_len(s_max, c)
    t = jnp.arange(s_max)
    t_mask = (t <= pos).astype(jnp.float32)
    oh = (
        ((t // seg)[None, :] == jnp.arange(c)[:, None]).astype(jnp.float32)
        * t_mask[None, :]
    )  # (c, S)
    counts = landmark_counts(jnp.asarray(pos), s_max, c)
    scale = dh ** -0.5

    def layer(key):
        ks = jax.random.split(key, 3)
        kk = jax.random.normal(ks[0], (1, hkv, s_max, dh)) * 0.5 * t_mask[:, None]
        vv = jax.random.normal(ks[1], (1, hkv, s_max, dh)) * t_mask[:, None]
        qq = jax.random.normal(ks[2], (1, h, s_max, dh)) * 0.5 * t_mask[:, None]
        q_lmk = jnp.einsum("cs,bhsd->bhcd", oh, qq)
        k_lmk = jnp.einsum("cs,bhsd->bhcd", oh, kk)
        kb = _broadcast_kv(kk, h)
        vb = _broadcast_kv(vv, h)
        m, l, acc = recompute_stats(
            landmark_means(q_lmk, counts), kb, vb, pos, scale,
            row_valid=counts > 0,
        )
        return {
            "k": kk, "v": vv, "q_lmk": q_lmk, "k_lmk": k_lmk,
            "bv_m": m, "bv_l": l, "bv_acc": acc,
        }

    keys = jax.random.split(key, cfg.num_layers)
    if cfg.scan_layers:
        layers = jax.vmap(layer)(keys)
    else:
        layers = [layer(k) for k in keys]
    return {"pos": jnp.asarray(pos + 1, jnp.int32), "layers": layers}


# --------------------------------------------------------------------------
# Analytic per-tick HBM-bytes accounting (per lane; the cells run 1 lane).
# --------------------------------------------------------------------------
def _tick_bytes(kv: PagedKVCache, mode: str, impl: str, nb_view: int) -> float:
    """Modelled HBM traffic of one decode tick, from the storage layout.

    seq-leaf token row = bytes of one token across a leaf's non-seq dims;
    ``view`` = nb_view blocks of that; ``block`` = one block. Both pool
    ticks additionally re-zero the reserved ZERO_BLOCK every tick (the
    inactive-lane dump target) — one more block write each.

    dense  : horizon read (mode-dependent) + in-place token write + dense 2x
    gather : 2x view (pool read + dense-view write) + horizon read +
             2x block (commit read+write) + 1x block (ZERO_BLOCK re-zero)
             + dense 2x
    paged  : horizon read via the kernel (single pool pass, exact only) +
             1x block commit + 1x block (ZERO_BLOCK re-zero) + dense 2x
    """
    seq_token = 0.0
    dense_rw = 0.0
    for arr, info in zip(kv._storage, kv.infos):
        it = arr.dtype.itemsize
        if info.seq_axis is None:
            # lane-dense leaf: per-lane slice read + write each tick
            dense_rw += 2.0 * float(np.prod(info.spec.shape)) * it
        else:
            shape = info.spec.shape
            row = float(np.prod(shape)) / shape[info.seq_axis] * it
            seq_token += row
    view = nb_view * kv.block_size * seq_token
    block = kv.block_size * seq_token
    # Horizon bytes the attention math itself reads: recompute rebuilds
    # B/BV over all K/V; exact reads them once for the active row; frozen
    # reads nothing between boundaries.
    horizon = {"recompute": view, "exact": view, "frozen": 0.0}[mode]
    if impl == "dense":
        return horizon + seq_token + dense_rw
    if impl == "gather":
        return 2.0 * view + horizon + 3.0 * block + dense_rw
    if impl == "paged":
        return horizon + 2.0 * block + dense_rw
    raise ValueError(impl)


# --------------------------------------------------------------------------
# Cells.
# --------------------------------------------------------------------------
def _dense_cell(rows, cfg, params, horizon: int, mode: str, tokens: int):
    mcfg = dataclasses.replace(cfg, decode_streaming=mode)
    seg = segment_len(horizon, mcfg.num_landmarks)
    pos0 = horizon - tokens - 2
    cache = _synthetic_cache(mcfg, horizon, pos0, jax.random.PRNGKey(1))
    step = jax.jit(
        lambda c, t: decode_step(params, mcfg, c, t), donate_argnums=(0,)
    )
    tok = jnp.ones((1, 1), jnp.int32)
    _, cache = step(cache, tok)  # compile + warmup (advances pos by 1)
    rebase_ms = 0.0
    if mode == "frozen":
        # Time the boundary-rebase program on its own; the steady-state
        # per-token cost charges one rebase per segment (seg tokens).
        rebase = jax.jit(make_rebase_fn(mcfg, horizon), donate_argnums=(0,))
        cache = rebase(cache, jnp.asarray(pos0 + 1))  # compile
        jax.block_until_ready(jax.tree.leaves(cache)[0])
        t0 = time.perf_counter()
        for _ in range(2):
            cache = rebase(cache, jnp.asarray(pos0 + 1))
        jax.block_until_ready(jax.tree.leaves(cache)[0])
        rebase_ms = (time.perf_counter() - t0) / 2 * 1e3
        _record(rows, "dense", horizon, mode, "rebase_ms", rebase_ms)
    jax.block_until_ready(jax.tree.leaves(cache)[0])
    t0 = time.perf_counter()
    for _ in range(tokens):
        logits, cache = step(cache, tok)
    jax.block_until_ready(logits)
    ms = (time.perf_counter() - t0) / tokens * 1e3 + rebase_ms / seg
    _record(rows, "dense", horizon, mode, "per_token_ms", ms)
    return ms


def _pool_cell(rows, cfg, params, horizon: int, mode: str, tokens: int,
               impl: str, cost_check: bool = False):
    """Block-pool storage cell: ``impl`` = "gather" (legacy dense-view
    tick) or "paged" (gather-free block-table kernel tick).

    ``cost_check=True`` additionally records XLA's own ``cost_analysis()``
    flops/bytes for the tick program (telemetry/accounting.py) and the
    ratio against the analytic ``_tick_bytes`` model — the cross-check is
    the RATIO's stability, not its value: XLA charges scatter/dynamic-
    update at full-operand size regardless of in-place aliasing (see the
    module docstring), so the ratio sits far above 1 by construction and a
    drift in it flags either a layout change or a cost-model change."""
    mcfg = dataclasses.replace(cfg, decode_streaming=mode)
    seg = segment_len(horizon, mcfg.num_landmarks)
    # Fixed serving-style block size across horizons: the paged tick's
    # "one block" commit term must not scale with S for the frozen-mode
    # bytes-flat claim to be a measured fact rather than a block-size
    # artifact. (Pre-PR5 CSVs used horizon//64 here.)
    block = 64
    serve = ServeConfig(max_lanes=1, max_seq=horizon, block_size=block)
    kv = PagedKVCache(mcfg, serve)
    alloc = BlockAllocator(serve.resolved_num_blocks, serve.block_size)
    pos0 = horizon - tokens - 2
    alloc.alloc(0, alloc.blocks_for_tokens(pos0 + 1))
    tables = np.full((1, serve.blocks_per_lane), ZERO_BLOCK, np.int32)
    row = alloc.tables[0]
    tables[0, : len(row)] = row
    cache = _synthetic_cache(mcfg, horizon, pos0, jax.random.PRNGKey(1))
    kv.write_prefill(0, cache, tables[0], n_tokens=pos0 + 1)
    step = functools.partial(decode_step, params, mcfg, seq_max=horizon)
    if impl == "paged":
        pstep = functools.partial(
            step, paged_meta=(block, mcfg.kernels_interpret)
        )
        fused = kv.make_paged_step(
            lambda c, t, tb: pstep(c, t, paged_table=tb)
        )
    else:
        fused = kv.make_fused_step(jax.vmap(step))
    nb = kv.view_blocks_needed(np.asarray([horizon - 1]), [0])
    tok = np.ones((1, 1, 1), np.int32)
    active = np.asarray([True])

    def tick(pos):
        nonlocal tables
        need = pos // block
        if need >= len(alloc.tables[0]):
            alloc.alloc(0, 1)
            tables = np.full((1, serve.blocks_per_lane), ZERO_BLOCK, np.int32)
            tables[0, : len(alloc.tables[0])] = alloc.tables[0]
        logits, new_storage = fused(
            kv._storage, jnp.asarray(tables), jnp.asarray(tok),
            jnp.asarray([pos], np.int32), jnp.asarray(active), nb,
        )
        kv._storage = list(new_storage)
        return logits

    lg = tick(pos0 + 1)  # compile + warmup
    rebase_ms = 0.0
    if mode == "frozen":
        # Boundary rebase (gather route in both impls — it recomputes two
        # rows over the horizon and commits only dense stats leaves).
        rebase = kv.make_rebase_step(jax.vmap(make_rebase_fn(mcfg, horizon)))

        def run_rebase(pos):
            kv._storage = list(rebase(
                kv._storage, jnp.asarray(tables),
                jnp.asarray([pos], np.int32), jnp.asarray(active), nb,
            ))

        run_rebase(pos0 + 1)  # compile
        jax.block_until_ready(kv._storage[0])
        t0 = time.perf_counter()
        for _ in range(2):
            run_rebase(pos0 + 1)
        jax.block_until_ready(kv._storage[0])
        rebase_ms = (time.perf_counter() - t0) / 2 * 1e3
        _record(rows, impl, horizon, mode, "rebase_ms", rebase_ms)
    jax.block_until_ready(lg)
    t0 = time.perf_counter()
    for i in range(tokens):
        lg = tick(pos0 + 2 + i)
    jax.block_until_ready(lg)
    ms = (time.perf_counter() - t0) / tokens * 1e3 + rebase_ms / seg
    model_bytes = _tick_bytes(kv, mode, impl, nb)
    _record(rows, impl, horizon, mode, "per_token_ms", ms)
    _record(rows, impl, horizon, mode, "per_token_bytes", model_bytes)
    if cost_check:
        from repro.telemetry.accounting import compiled_cost

        cost = compiled_cost(
            fused._jitted, kv._storage, jnp.asarray(tables)[:, :nb],
            jnp.asarray(tok), jnp.asarray([pos0 + 2], np.int32),
            jnp.asarray(active),
        )
        _record(rows, impl, horizon, mode, "xla_cost_flops", cost["flops"])
        _record(rows, impl, horizon, mode, "xla_cost_bytes", cost["bytes"])
        if cost["bytes"]:
            _record(rows, impl, horizon, mode, "xla_to_model_bytes",
                    cost["bytes"] / model_bytes)
    return ms


def write_json() -> None:
    from benchmarks.run import write_bench  # lazy: avoids an import cycle

    write_bench(
        "decode",
        schema="impl|mode|horizon -> {per_token_ms, per_token_bytes, "
               "rebase_ms?, xla_cost_bytes?, xla_cost_flops?}",
        extra={"impls": {
            "dense": "lane-dense decode_step (no paging)",
            "gather": "block pools + legacy gather/scatter tick",
            "paged": "block pools + gather-free block-table kernel tick",
        }},
        cells=_cells,
    )


def run(rows: list[str]) -> None:
    _cells.clear()
    cfg, params = _setup()
    if _smoke():
        horizons, tokens = (512,), 4
    else:
        horizons, tokens = (1024, 8192, 32768), 8
    for h in horizons:
        # Cost analysis AOT-compiles each tick program a second time, so
        # only the smallest horizon pays for the cross-check.
        cost_check = h == horizons[0]
        ms = {}
        for mode in MODES:
            ms[mode] = _dense_cell(rows, cfg, params, h, mode, tokens)
        for mode in MODES:
            _pool_cell(rows, cfg, params, h, mode, tokens, "gather",
                       cost_check=cost_check)
        for mode in ("exact", "frozen"):  # recompute stays gather-only
            _pool_cell(rows, cfg, params, h, mode, tokens, "paged",
                       cost_check=cost_check)
        rows.append(
            f"decode,dense_h{h},exact_speedup_vs_recompute,"
            f"{ms['recompute'] / max(ms['exact'], 1e-9):.2f}"
        )
        rows.append(
            f"decode,dense_h{h},frozen_speedup_vs_recompute,"
            f"{ms['recompute'] / max(ms['frozen'], 1e-9):.2f}"
        )
    write_json()


if __name__ == "__main__":
    out: list[str] = []
    run(out)
    print("name,case,metric,value")
    print("\n".join(out))
